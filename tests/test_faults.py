"""Fault-tolerant serving: deterministic fault injection, replica health
tracking and routing exclusion, dead-replica block reclamation (refcount
audited), token-identical request recovery via evict-to-recompute, and
structured deadline failures. Chaos property tests run seeded-random
always and add a hypothesis pass when the library is installed."""
import threading
import time
import types

import pytest

from repro.configs.base import get_config
from repro.core.engine_pool import (DisaggregatedEnginePool, EnginePool,
                                    build_pools, replicas_of)
from repro.core.teola import Teola
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import SimLLMEngine, build_sim_engines
from repro.serving import kv_cache as kvc
from repro.serving.faults import (DeadlineExceeded, FaultInjector,
                                  FaultSpec, FTConfig, MigrationFault,
                                  ReplicaCrash, RequestError,
                                  is_recoverable)
from repro.training.data import doc_corpus

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:                      # seeded-random tests still run
    HAVE_HYPOTHESIS = False

_CFG = get_config("tiny-lite-llm")
Q = {"question": "what is fact 3 about optics", "docs": doc_corpus(2)}

# fast-converging recovery knobs for tests (sim engines: passes are ms)
_FT = dict(max_retries=3, backoff=0.01, suspect_after=0.4, dead_after=0.8,
           watchdog_period=0.05)

# real-engine knobs: heartbeat thresholds must exceed the worst-case
# single decode pass (first pass JIT-compiles, which can take seconds) or
# the watchdog false-positives a busy replica as hung
_FT_REAL = dict(max_retries=3, backoff=0.05, suspect_after=20.0,
                dead_after=45.0, watchdog_period=0.2)


# ---------------------------------------------------------------------------
# FaultInjector: spec validation, parsing, determinism

def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("explode", "e", "decode")
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultSpec("crash", "e", "verify")
    with pytest.raises(ValueError, match="1-based"):
        FaultSpec("crash", "e", "decode", at=0)
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultInjector.parse("crash:only_two_fields")


def test_parse_roundtrip_and_defaults():
    inj = FaultInjector.parse(
        "crash:core_llm.r1:decode:3, slow:lite_llm:prefill:2:0.25,"
        "hang:core_llm:alloc")
    assert [(s.kind, s.engine, s.point, s.at) for s in inj.specs] == [
        ("crash", "core_llm.r1", "decode", 3),
        ("slow", "lite_llm", "prefill", 2),
        ("hang", "core_llm", "alloc", 1)]
    assert inj.specs[1].duration == 0.25


def test_injector_fires_at_exact_call_index_and_is_persistent():
    eng = types.SimpleNamespace(name="e0", health="healthy")
    inj = FaultInjector([FaultSpec("crash", "e0", "decode", at=3)])
    inj.fire(eng, "decode")
    inj.fire(eng, "decode")
    inj.fire(eng, "prefill")             # other points count separately
    with pytest.raises(ReplicaCrash, match="injected crash at decode"):
        inj.fire(eng, "decode")
    assert eng.health == "dead"
    assert inj.dead_replicas() == {"e0"}
    # the crash is persistent: EVERY later call on the replica fails
    with pytest.raises(ReplicaCrash, match="replica is dead"):
        inj.fire(eng, "prefill")
    assert inj.log == [("crash", "e0", "decode", 3)]


def test_random_schedule_is_seed_deterministic():
    names = ["a", "b", "c"]
    s1 = FaultInjector.random_schedule(names, seed=7, n_faults=4).specs
    s2 = FaultInjector.random_schedule(names, seed=7, n_faults=4).specs
    s3 = FaultInjector.random_schedule(names, seed=8, n_faults=4).specs
    assert s1 == s2
    assert s1 != s3


def test_arm_reaches_llm_replicas_only():
    engines = build_sim_engines(llm_instances=2)
    inj = FaultInjector()
    armed = inj.arm(engines)
    assert set(armed) == {"core_llm", "core_llm.r1",
                          "lite_llm", "lite_llm.r1"}
    for name in ("core_llm", "lite_llm"):
        assert all(r.faults is inj for r in replicas_of(engines[name]))
    assert getattr(engines["embedding"], "faults", None) is None


def test_is_recoverable_classification():
    assert is_recoverable(ReplicaCrash("x"))
    assert is_recoverable(MigrationFault("x"))
    assert is_recoverable(TimeoutError("x"))
    assert is_recoverable(kvc.OutOfBlocks("full"))
    assert is_recoverable(RuntimeError("decode loop died: boom"))
    assert not is_recoverable(KeyError("bug"))
    assert not is_recoverable(ValueError("bad shape"))


# ---------------------------------------------------------------------------
# EnginePool health tracking and routing exclusion

def test_pool_health_marking_and_routing_exclusion():
    pool = EnginePool.replicate(SimLLMEngine("llm"), 3, name="llm")
    assert [pool.health(i) for i in range(3)] == ["healthy"] * 3
    assert pool.least_loaded() == 0      # stable min, all healthy
    assert pool.mark_dead(0, "crashed")
    assert not pool.mark_dead(0, "again")        # only first transition
    assert pool.health(0) == "dead"
    assert pool.health_reason(0) == "crashed"
    assert pool.least_loaded() == 1      # dead replica excluded
    assert pool.least_loaded_decode() == 1
    pool.mark_suspect(1, "slow heartbeat")
    assert pool.health(1) == "suspect"
    assert pool.least_loaded() == 2      # suspect demoted below healthy
    pool.mark_healthy(1)
    assert pool.health(1) == "healthy"
    assert pool.least_loaded() == 1


def test_pool_health_merges_engine_attribute():
    """An injected crash sets engine.health directly; the pool view must
    reflect it without an explicit mark_dead call."""
    pool = EnginePool.replicate(SimLLMEngine("llm"), 2, name="llm")
    pool[1].health = "dead"
    assert pool.health(1) == "dead"
    assert pool.healthy_indices() == [0]


def test_all_dead_pool_falls_back_instead_of_crashing():
    pool = EnginePool.replicate(SimLLMEngine("llm"), 2, name="llm")
    pool.mark_dead(0), pool.mark_dead(1)
    # routing still returns an index (callers surface the error on use)
    assert pool.least_loaded() in (0, 1)


def test_suspect_does_not_break_affinity_or_capacity_keys():
    """Suspect demotion is a leading sort key: with every replica
    healthy the routing order is byte-identical to the pre-health pool."""
    pool = EnginePool.replicate(
        SimLLMEngine("llm", decode_ms_per_step=5.0), 2, name="llm")
    pool.note_queued(0, 500)
    assert pool.least_loaded() == 1      # load still decides


def test_disagg_routing_demotes_to_colocated_when_role_dies():
    reps = [SimLLMEngine(f"r{i}", paged=True, num_blocks=16)
            for i in range(2)]
    pool = DisaggregatedEnginePool(reps, n_prefill=1, name="core")
    assert list(pool.route_prefill_indices()) == [0]
    assert list(pool.route_decode_indices()) == [1]
    assert not pool.degraded()
    pool.mark_dead(1, "decode replica crashed")
    # the whole decode role is gone: decodes demote onto the prefill side
    assert list(pool.route_decode_indices()) == [0]
    assert pool.degraded()
    pool.mark_dead(0, "everything is on fire")
    # all dead: fall back to the static partition (callers fail on use)
    assert list(pool.route_decode_indices()) == [1]


# ---------------------------------------------------------------------------
# Satellite: OutOfBlocks carries allocator diagnostics

def test_out_of_blocks_message_carries_allocator_diagnostics():
    text = " ".join(f"w{i}" for i in range(20))
    probe = LLMEngine("pr", _CFG, max_len=128, seed=0, paged=True,
                      block_size=8)
    probe.op_prefill([{"sid": "bg", "text": text}])
    nb = len(probe.states["bg"].table)
    eng = LLMEngine("d", _CFG, max_len=128, seed=0, paged=True,
                    block_size=8, num_blocks=nb + 1)  # capacity == nb
    eng.ALLOC_TIMEOUT = 0.1
    eng.op_prefill([{"sid": "bg", "text": text}])     # fills the pool
    with pytest.raises(kvc.OutOfBlocks) as e:
        eng.op_prefill([{"sid": "s2", "text": " ".join(
            f"v{i}" for i in range(20))}])
    msg = str(e.value)
    for frag in ("diag:", "reserved=", "evictable_radix=", "waiters=",
                 "resident_seqs="):
        assert frag in msg, f"missing {frag!r} in {msg!r}"


def test_allocator_snapshot_audit_and_waiter_count():
    a = kvc.BlockAllocator(8)
    held = kvc.reserve_blocks(a, 3)
    snap = a.snapshot()
    assert snap["capacity"] == 7 and snap["used"] == 3
    assert a.audit()["ok"]
    # exhaust the pool so the waiter actually blocks
    rest = kvc.reserve_blocks(a, a.free_blocks())
    t = threading.Thread(target=lambda: a.wait_for_free(1, timeout=0.3))
    t.start()
    time.sleep(0.1)
    assert a.waiters() == 1
    t.join()
    assert a.waiters() == 0              # decremented on timeout too
    for b in held + rest:
        a.decref(b)
    assert a.audit() == {"ok": True, "leaked": 0, "bad_free": 0,
                         "free": 7, "capacity": 7}


# ---------------------------------------------------------------------------
# Satellite: decode-loop death surfaces the first exception + marks health

def test_injected_crash_mid_decode_fails_sequence_and_marks_dead():
    eng = SimLLMEngine("llm", max_batch=2)
    eng.faults = FaultInjector([FaultSpec("crash", "llm", "decode", at=2)])
    seq = eng.submit_decode("s", 6)
    with pytest.raises(ReplicaCrash, match="injected crash"):
        seq.wait(60)
    assert eng.health == "dead"
    assert is_recoverable(seq.error)
    eng.stop_decode_loop()


def test_loop_thread_death_is_captured_not_swallowed():
    """Satellite bugfix: an exception in the loop INFRASTRUCTURE (outside
    the per-iteration engine call) must surface to every waiter as a
    'decode loop died' error with the original cause attached, and mark
    the owning engine suspect — not vanish with the thread."""
    eng = SimLLMEngine("llm", max_batch=2,
                       decode_ms_per_step=50.0)
    loop = eng.start_decode_loop()

    def boom(batch):
        raise KeyError("loop bookkeeping bug")

    loop._decode_cost = boom
    seq = eng.submit_decode("s", 4)
    with pytest.raises(RuntimeError, match="decode loop died"):
        seq.wait(60)
    assert isinstance(seq.error.__cause__, KeyError)
    assert isinstance(loop.fatal_error, KeyError)
    assert eng.health == "suspect"


def test_decode_loop_heartbeat_advances():
    eng = SimLLMEngine("llm")
    seq = eng.submit_decode("s", 3)
    t0 = eng._decode_loop.last_pass
    assert seq.wait(60)
    assert eng._decode_loop.last_pass >= t0
    eng.stop_decode_loop()


# ---------------------------------------------------------------------------
# reclaim_replica: dead-replica block reclamation with refcount audit

def _paged_engine(**kw):
    kw.setdefault("num_blocks", 32)
    return LLMEngine("p", _CFG, max_len=256, seed=0, paged=True,
                     block_size=8, **kw)


def test_reclaim_replica_returns_all_blocks_and_audits_clean():
    eng = _paged_engine(prefix_cache="radix")
    text = " ".join(f"w{i}" for i in range(16))
    eng.op_prefill([{"sid": "s0", "text": text + " alpha"},
                    {"sid": "s1", "text": text + " beta"}])
    assert eng.alloc.used_blocks() > 0
    assert eng.radix.num_blocks() > 0    # tree co-owns prefix blocks
    report = kvc.reclaim_replica(eng)
    assert report["ok"] and not report["written_off"]
    assert report["leaked"] == 0
    assert report["released"] == 2       # both resident sequences
    assert report["radix_refs"] > 0
    assert eng.alloc.free_blocks() == eng.alloc.capacity
    assert eng.alloc.audit()["ok"]
    assert eng.states == {} and eng.radix.num_blocks() == 0


def test_reclaim_replica_writes_off_when_lock_is_hung():
    eng = _paged_engine()
    eng.op_prefill([{"sid": "s", "text": "a few words here"}])
    grabbed, done = threading.Event(), threading.Event()

    def wedge():                         # RLock: must hang from another thread
        with eng._paged_lock:
            grabbed.set()
            done.wait(5)

    t = threading.Thread(target=wedge, daemon=True)
    t.start()
    assert grabbed.wait(5)
    try:
        report = kvc.reclaim_replica(eng, lock_timeout=0.1)
    finally:
        done.set()
        t.join(5)
    assert report["written_off"] and not report["ok"]
    assert "s" in eng.states             # nothing touched after write-off


def test_recovery_manager_marks_dead_once_and_reclaims():
    pool = EnginePool.replicate(
        SimLLMEngine("llm", paged=True, num_blocks=32), 2, name="llm")
    sched = types.SimpleNamespace(pool=pool, affinity={},
                                  _aff_lock=threading.Lock())
    from repro.serving.faults import RecoveryManager
    mgr = RecoveryManager(sched, FTConfig(**_FT))
    mgr.note_failure(1, ReplicaCrash("boom"))
    assert pool.health(1) == "dead"
    assert len(mgr.reclaim_reports) == 1
    mgr.note_failure(1, ReplicaCrash("boom again"))       # no double reclaim
    assert len(mgr.reclaim_reports) == 1
    # capacity errors do NOT mark health: the replica is healthy-but-full
    mgr.note_failure(0, kvc.OutOfBlocks("full"))
    assert pool.health(0) == "healthy"
    mgr.note_failure(0, RuntimeError("some bug"))
    assert pool.health(0) == "suspect"
    assert mgr.pick_replica(exclude={1}) == 0     # suspect beats dead
    mgr.stop()


# ---------------------------------------------------------------------------
# recover_decode: token-identical evict-to-recompute replay

def test_recover_decode_token_identical_real_engine():
    text = "alpha beta gamma delta epsilon zeta"
    a = _paged_engine()
    a.op_prefill([{"sid": "s", "text": text}])
    ref = a.submit_decode("s", 8)
    assert ref.wait(120)
    a.stop_decode_loop()

    for cut in (0, 3, 8):                # nothing / mid-flight / finished
        b = a.clone(1)
        failed = types.SimpleNamespace(tokens=ref.tokens[:cut])
        sq = b.recover_decode("s", text, 8, failed)
        assert sq.wait(120), f"recovery at cut={cut} timed out"
        assert sq.result == ref.result, f"divergence at cut={cut}"
        assert sq.tokens == ref.tokens
        b.stop_decode_loop()


def test_recover_decode_without_failed_handle():
    """Affinity pointed at a replica that died before emitting anything:
    replay is just prefill + full decode."""
    text = "one two three four five"
    a = _paged_engine()
    a.op_prefill([{"sid": "s", "text": text}])
    ref = a.submit_decode("s", 6)
    assert ref.wait(120)
    a.stop_decode_loop()
    b = a.clone(1)
    sq = b.recover_decode("s", text, 6, None)
    assert sq.wait(120) and sq.result == ref.result
    b.stop_decode_loop()


def test_migration_fault_leaves_source_intact_and_is_retryable():
    pe = _paged_engine()
    de = pe.clone(1)
    pe.op_prefill([{"sid": "s", "text": "some words to migrate over"}])
    nb = pe.alloc.used_blocks()
    de.faults = FaultInjector([FaultSpec("migrate_fail", "p.r1",
                                         "migrate", at=1)])
    with pytest.raises(MigrationFault):
        de.import_seq(pe.export_seq("s"))
    assert "s" in pe.states and pe.alloc.used_blocks() == nb
    assert de.alloc.used_blocks() == 0
    # the fault was one-shot: the retry lands the same handle
    assert de.import_seq(pe.export_seq("s")) is None
    assert de.alloc.used_blocks() == nb and "s" not in pe.states


# ---------------------------------------------------------------------------
# End-to-end recovery through Teola (sim engines)

def _sim_orch(injector=None, llm_instances=2, ft=None, **cfg):
    engines = build_sim_engines(llm_instances=llm_instances,
                                paged_kv=True, **cfg)
    if injector is not None:
        injector.arm(engines)
    from repro.core.apps import naive_rag
    orch = Teola(naive_rag(engines), engines, continuous_batching=True,
                 fault_tolerance=ft)
    return orch, engines


def _ftmgr(orch, name="core_llm"):
    return orch.runtime.scheds[name].ftmgr


def test_e2e_sim_crash_recovery_completes_query():
    inj = FaultInjector([FaultSpec("crash", "core_llm", "decode", at=1)])
    orch, engines = _sim_orch(inj, ft=FTConfig(**_FT))
    try:
        out, ctx = orch.query(dict(Q), timeout=120)
        assert ctx.error is None and out
        assert inj.log and inj.log[0][0] == "crash"
        mgr = _ftmgr(orch)
        kinds = [e[0] for e in mgr.events]
        assert "replica_dead" in kinds and "retry" in kinds
        assert engines["core_llm"].health(0) == "dead"
        # a second query routes around the dead replica
        out2, ctx2 = orch.query(dict(Q), timeout=120)
        assert ctx2.error is None and out2
    finally:
        orch.shutdown()


def test_e2e_sim_hang_detected_by_watchdog_and_recovered():
    inj = FaultInjector([FaultSpec("hang", "core_llm", "decode", at=1,
                                   duration=3.0)])
    orch, _ = _sim_orch(inj, ft=FTConfig(**_FT))
    try:
        out, ctx = orch.query(dict(Q), timeout=120)
        assert ctx.error is None and out
        mgr = _ftmgr(orch)
        assert any(e[0] == "replica_dead" and "heartbeat" in e[2]
                   for e in mgr.events), mgr.events
    finally:
        orch.shutdown()


def test_e2e_deadline_fails_structurally_instead_of_hanging():
    # hang BOTH replicas: load-aware routing may put every decode of the
    # query on either one, and an unhung replica would finish in time
    inj = FaultInjector([FaultSpec("hang", "core_llm", "decode", at=1,
                                   duration=6.0),
                         FaultSpec("hang", "core_llm.r1", "decode", at=1,
                                   duration=6.0)])
    ft = FTConfig(max_retries=0, request_deadline=0.6,
                  # hang detection slower than the deadline: the request
                  # must die on ITS clock, not on replica recovery
                  suspect_after=30.0, dead_after=60.0,
                  watchdog_period=0.05)
    orch, _ = _sim_orch(inj, ft=ft)
    t0 = time.time()
    try:
        with pytest.raises(DeadlineExceeded) as e:
            orch.query(dict(Q), timeout=60)
        assert time.time() - t0 < 30     # failed loudly, no hang
        assert e.value.reason == "deadline"
        assert e.value.qid and e.value.sid
        assert any(ev[0] == "deadline" for ev in _ftmgr(orch).events)
    finally:
        orch.shutdown()


def test_e2e_unrecoverable_error_fails_with_structured_error():
    """max_retries=0 turns the first crash into a loud RequestError with
    full context, not a bare thread exception."""
    # crash BOTH replicas: load-aware routing may put the query's decodes
    # on either one, and the uncrashed replica would serve them cleanly
    inj = FaultInjector([FaultSpec("crash", "core_llm", "decode", at=1),
                         FaultSpec("crash", "core_llm.r1", "decode", at=1)])
    orch, _ = _sim_orch(inj, ft=FTConfig(
        max_retries=0, backoff=0.01, watchdog_period=0.05))
    try:
        with pytest.raises(RequestError) as e:
            orch.query(dict(Q), timeout=120)
        assert e.value.qid.startswith("q")
        assert e.value.replica.startswith("core_llm")
    finally:
        orch.shutdown()


def test_ft_flag_off_keeps_scheduler_paths_identical():
    orch, _ = _sim_orch(None, ft=None)
    try:
        assert _ftmgr(orch) is None
        out, ctx = orch.query(dict(Q), timeout=120)
        assert ctx.error is None and out
    finally:
        orch.shutdown()


# ---------------------------------------------------------------------------
# Chaos property tests: seeded random schedules; every query either
# completes or fails with a structured error, and no replica leaks blocks.

def _chaos_trial(seed: int):
    names = ["core_llm", "core_llm.r1", "lite_llm", "lite_llm.r1"]
    inj = FaultInjector.random_schedule(
        names, seed=seed, n_faults=2, kinds=("crash", "slow"),
        points=("decode", "prefill"), max_at=4)
    orch, engines = _sim_orch(inj, ft=FTConfig(**_FT))
    try:
        ctxs = [orch.submit(dict(Q)) for _ in range(3)]
        for c in ctxs:
            assert c.done.wait(120), f"seed {seed}: query hung"
            if c.error is not None:
                assert isinstance(c.error, RequestError), \
                    f"seed {seed}: unstructured {c.error!r}"
        # block conservation on every replica that is still alive;
        # reclaimed (dead) replicas were audited by reclaim_replica
        for name in ("core_llm", "lite_llm"):
            mgr = _ftmgr(orch, name)
            for rep in mgr.reclaim_reports:
                assert rep.get("written_off") or rep.get("leaked") == 0, rep
            pool = engines[name]
            for i in range(len(pool)):
                alloc = getattr(pool[i], "alloc", None)
                if alloc is not None and pool.health(i) != "dead":
                    assert alloc.audit()["bad_free"] == 0
    finally:
        orch.shutdown()


@pytest.mark.parametrize("seed", [11, 23, 47])
def test_chaos_seeded_random_schedules(seed):
    _chaos_trial(seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=4, deadline=None)
    @given(seed=hst.integers(0, 10_000))
    def test_chaos_hypothesis_schedules(seed):
        _chaos_trial(seed)


# ---------------------------------------------------------------------------
# Acceptance: real engines, 4 replicas, kill one mid-decode — every
# request completes token-identical to the no-fault baseline and no
# paged blocks leak.

def _real_pool_run(injector, ft):
    from repro.core.apps import build_engines, naive_rag
    engines = build_engines(paged_kv=True)
    engines = build_pools(engines, {"core_llm": 4})
    if injector is not None:
        injector.arm(engines)
    orch = Teola(naive_rag(engines), engines, continuous_batching=True,
                 fault_tolerance=ft)
    try:
        out, ctx = orch.query(dict(Q), timeout=600)
        assert ctx.error is None
        return out, engines, orch
    finally:
        orch.shutdown()


def test_real_engine_replica_kill_is_token_identical():
    baseline, _, _ = _real_pool_run(None, None)
    inj = FaultInjector([FaultSpec("crash", "core_llm", "decode", at=2)])
    out, engines, orch = _real_pool_run(inj, FTConfig(**_FT_REAL))
    assert inj.log, "fault never fired (routing changed?)"
    assert out == baseline               # token-identical recovery
    pool = engines["core_llm"]
    assert pool.health(0) == "dead"
    mgr = orch.runtime.scheds["core_llm"].ftmgr
    assert any(e[0] == "retry" for e in mgr.events), mgr.events
    for rep in mgr.reclaim_reports:
        assert rep["leaked"] == 0 and rep["ok"], rep
    for i in range(len(pool)):
        if pool.health(i) != "dead":
            assert pool[i].alloc.audit()["ok"]
            assert pool[i].alloc.free_blocks() == pool[i].alloc.capacity


# ---------------------------------------------------------------------------
# serve.py flag validation (table-driven, like the disagg suite)

def _validate(argv):
    from repro.launch.serve import build_parser, validate_args
    ap = build_parser()
    args = ap.parse_args(argv)
    validate_args(ap, args)
    return args


@pytest.mark.parametrize("argv,msg", [
    (["--fault-inject", "crash:core_llm:decode:1"],
     "--continuous-batching"),
    (["--request-deadline", "5"], "--continuous-batching"),
    (["--max-retries", "3"], "--continuous-batching"),
    (["--continuous-batching", "--fault-inject", "x"], "bad fault spec"),
    (["--continuous-batching", "--fault-inject",
      "explode:core_llm:decode:1"], "unknown fault kind"),
    (["--continuous-batching", "--request-deadline", "0"],
     "--request-deadline must be > 0"),
    (["--continuous-batching", "--max-retries", "-1"],
     "--max-retries must be >= 0"),
    (["--continuous-batching", "--scheme", "LlamaDist-TO",
      "--max-retries", "1"], "--scheme Teola"),
])
def test_serve_rejects_bad_fault_flags(argv, msg, capsys):
    with pytest.raises(SystemExit) as e:
        _validate(argv)
    assert e.value.code == 2
    assert msg in capsys.readouterr().err


def test_serve_accepts_fault_flags():
    args = _validate(["--continuous-batching", "--fault-inject",
                      "crash:core_llm.r1:decode:3", "--request-deadline",
                      "10", "--max-retries", "1"])
    assert args.fault_tolerance_on
    args = _validate([])
    assert not args.fault_tolerance_on   # plain serve untouched
