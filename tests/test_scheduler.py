"""Engine-scheduler batching policy unit tests (Algorithm 2)."""
import time


from repro.core import primitives as P
from repro.core.primitives import Graph, Primitive
from repro.core.runtime import EngineScheduler, NodeTask, QueryContext


class FakeEngine:
    def __init__(self, max_batch=4):
        self.kind = "fake"
        self.max_batch = max_batch


def _ctx():
    return QueryContext(Graph(), {})


def _task(ctx, depth, op=P.PREFILL, nreq=1, t=None):
    p = Primitive(op=op, engine="fake", component="c")
    p.depth = depth
    p.num_requests = nreq
    task = NodeTask(p, ctx)
    if t is not None:
        task.t_arrival = t
    return task


def _sched(policy, max_batch=4):
    s = EngineScheduler(FakeEngine(max_batch), lambda e, b: None, policy)
    return s


def test_topo_prioritizes_depth_within_query():
    s = _sched("topo")
    ctx = _ctx()
    shallow = _task(ctx, depth=0, t=1.0)
    deep = _task(ctx, depth=5, t=2.0)
    s.pending = [shallow, deep]
    batch = s._form_batch()
    assert batch[0] is deep            # higher depth first despite arrival


def test_topo_buckets_by_query_earliest_first():
    s = _sched("topo", max_batch=2)
    c1, c2 = _ctx(), _ctx()
    a = _task(c1, depth=1, t=1.0)      # query 1 arrives first
    b = _task(c1, depth=0, t=1.1)
    g = _task(c2, depth=9, t=2.0)      # query 2 later but deeper
    s.pending = [g, a, b]
    batch = s._form_batch()
    # paper Fig 7: batch A (deepest of q1) with H (deepest of q2), NOT A+B
    assert a in batch and g in batch and b not in batch


def test_topo_respects_slots_by_request_count():
    s = _sched("topo", max_batch=4)
    ctx = _ctx()
    big = _task(ctx, depth=3, nreq=3)
    small = _task(ctx, depth=2, nreq=2)
    tiny = _task(ctx, depth=1, nreq=1)
    s.pending = [tiny, small, big]
    batch = s._form_batch()
    assert big in batch
    assert sum(t.prim.num_requests for t in batch) <= 4


def test_to_fifo_fills_batch():
    s = _sched("to", max_batch=3)
    c1, c2 = _ctx(), _ctx()
    t1 = _task(c1, 0, t=1.0)
    t2 = _task(c2, 0, t=2.0)
    t3 = _task(c1, 0, t=3.0)
    t4 = _task(c2, 0, t=4.0)
    s.pending = [t4, t2, t1, t3]
    batch = s._form_batch()
    assert batch == [t1, t2, t3]


def test_po_bundles_one_invocation():
    s = _sched("po", max_batch=8)
    c1, c2 = _ctx(), _ctx()
    a1 = _task(c1, 0, t=1.0)
    a2 = _task(c1, 0, t=1.0)
    b1 = _task(c2, 0, t=0.5)          # earlier arrival, other query
    s.pending = [a1, a2, b1]
    batch = s._form_batch()
    assert batch == [b1]              # strictly one query's bundle


def test_batch_is_op_homogeneous():
    s = _sched("topo")
    ctx = _ctx()
    p1 = _task(ctx, depth=3, op=P.PREFILL)
    d1 = _task(ctx, depth=3, op=P.DECODE)
    s.pending = [p1, d1]
    batch = s._form_batch()
    assert len({t.prim.op for t in batch}) == 1


def test_scheduler_thread_executes_and_calls_back():
    done = []
    s = EngineScheduler(FakeEngine(),
                        lambda e, b: [t.ctx.store.update({"x": 1})
                                      for t in b],
                        "topo")
    s.on_complete = lambda t: done.append(t)
    s.start()
    ctx = _ctx()
    s.submit(_task(ctx, 0))
    for _ in range(200):
        if done:
            break
        time.sleep(0.005)
    s.stop()
    assert done and done[0].ctx.store.get("x") == 1
