"""Sim-engine latency profiles must keep tracking the paper's anchors —
if someone retunes them, these tests pin the calibration."""

from repro.engines.sim_engines import (SPEED, SimEmbeddingEngine,
                                       SimLLMEngine)


def test_prefill_anchors_table3():
    """Paper Table 3 single-prefill: 1000 tok -> ~260 ms,
    3000 tok -> ~720 ms (llama-2-7B)."""
    eng = SimLLMEngine("t")
    eng.op_prefill([{"sid": "a", "text": " ".join(["w"] * 1000)}])
    ms1000 = eng.stats["busy_ms"]
    eng.stats["busy_ms"] = 0
    eng.op_prefill([{"sid": "b", "text": " ".join(["w"] * 3000)}])
    ms3000 = eng.stats["busy_ms"]
    assert 200 < ms1000 < 330
    assert 600 < ms3000 < 850


def test_prefill_batch_discount_fig7():
    """Fig 7: one 512-tok prefill 0.5 s; batch of two 0.8 s."""
    eng = SimLLMEngine("t")
    eng.op_prefill([{"sid": "a", "text": " ".join(["w"] * 512)}])
    single = eng.stats["busy_ms"]
    eng.stats["busy_ms"] = 0
    eng.op_prefill([{"sid": "b", "text": " ".join(["w"] * 512)},
                    {"sid": "c", "text": " ".join(["w"] * 512)}])
    batch2 = eng.stats["busy_ms"]
    assert 1.3 < batch2 / single < 1.8          # ~1.6x for 2x work


def test_embedding_total_time_anchor_fig4():
    """48 requests: batch 4 ~1.8 s, batch 16 ~1.35 s."""
    t = {}
    for bs in (4, 16):
        eng = SimEmbeddingEngine(max_batch=bs)
        for i in range(0, 48, bs):
            eng.op_embed([{"texts": [f"c{j}" for j in range(i, i + bs)]}])
        t[bs] = eng.stats["busy_ms"]
    assert 1500 < t[4] < 2100
    assert 1100 < t[16] < 1600


def test_decode_step_cost():
    eng = SimLLMEngine("t")
    eng.op_decode([{"sid": "a", "max_new": 10}])
    per_step = eng.stats["busy_ms"] / 10
    assert 20 <= per_step <= 30                  # ~25 ms/step (13B-class)


def test_sleep_respects_speed_factor():
    import time
    eng = SimLLMEngine("t")
    t0 = time.time()
    eng.op_decode([{"sid": "a", "max_new": 8}])
    wall = (time.time() - t0) * 1000
    modeled = eng.stats["busy_ms"]
    assert wall < modeled / SPEED * 2.5 + 20     # scaled down by SPEED
