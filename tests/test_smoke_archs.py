"""Per-architecture smoke tests: a REDUCED variant of each assigned config
(<=2-ish layers, d_model<=256, <=4 experts) runs one forward and one train
step on CPU; output shapes and NaN-freeness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import ASSIGNED
from repro.configs.base import get_config
from repro.models.transformer import apply_model, init_params
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_nan(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 4
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_params(cfg, jax.random.key(0))
    B, S = 2, 16
    if cfg.embed_stub:
        inputs = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model))
    else:
        inputs = jax.random.randint(jax.random.key(1), (B, S), 0,
                                    cfg.vocab_size)
    logits, cache, aux = apply_model(cfg, params, inputs)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert cache is None
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.key(0))
    oc = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    opt = init_opt_state(oc, params)
    stub = cfg.embed_stub is not None
    step = jax.jit(make_train_step(cfg, oc, compute_dtype=jnp.float32,
                                   q_block=64, stub=stub))
    B, S = 2, 16
    if stub:
        batch = {"embeds": jax.random.normal(jax.random.key(1),
                                             (B, S, cfg.d_model)),
                 "targets": jax.random.randint(jax.random.key(2), (B, S), 0,
                                               cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S + 1),
                                              0, cfg.vocab_size)}
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["gnorm"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(new_params)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_exact_assigned_config_numbers(arch):
    """The FULL configs carry the exact pool numbers (exercised via the
    dry-run only — no allocation here)."""
    cfg = get_config(arch)
    expected = {
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 5632, 151936),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)
    assert cfg.citation


def test_param_counts_plausible():
    assert 0.9e9 < get_config("tinyllama-1.1b").param_count() < 1.4e9
    assert 55e9 < get_config("deepseek-67b").param_count() < 80e9
    assert 8e9 < get_config("gemma2-9b").param_count() < 11e9
    ds = get_config("deepseek-v3-671b")
    assert 55e10 < ds.param_count() < 80e10
    assert ds.active_param_count() < 0.1 * ds.param_count()
    assert 2.5e9 < get_config("rwkv6-3b").param_count() < 4e9
