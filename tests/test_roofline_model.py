"""Roofline cost-model unit tests: analytic formulas + nested HLO
collective accounting."""

from repro.configs.base import INPUT_SHAPES, get_config
from repro.launch.roofline_model import (analytic_bytes, analytic_flops,
                                         collective_bytes_nested,
                                         linear_flops, trips_for_case)


def test_analytic_flops_close_to_6nd_for_dense_train():
    cfg = get_config("tinyllama-1.1b")
    ish = INPUT_SHAPES["train_4k"]
    got = analytic_flops(cfg, ish)
    model = 6.0 * cfg.active_param_count() * ish.global_batch * ish.seq_len
    # implemented program does full-S^2 attention -> got >= model flops
    assert model * 0.8 < got < model * 2.5


def test_decode_flops_scale_with_batch_not_seq():
    cfg = get_config("tinyllama-1.1b")
    d32 = INPUT_SHAPES["decode_32k"]
    f = analytic_flops(cfg, d32)
    # decode processes B tokens; linear part = 2*N*B
    lin = linear_flops(cfg, d32.global_batch)
    assert f > lin                      # + attention over the 32k cache
    assert f < lin * 10


def test_analytic_bytes_decode_dominated_by_cache_and_weights():
    from repro.serving.kv_cache import cache_bytes
    cfg = get_config("deepseek-67b")
    ish = INPUT_SHAPES["decode_32k"]
    b = analytic_bytes(cfg, ish, 256)
    w = cfg.param_count() * 2 / 256
    kv = cache_bytes(cfg, ish.global_batch, ish.seq_len) / 256
    assert 0.9 * (w + kv) < b < 1.5 * (w + kv)


HLO = """
%layer_body.1 (p: (f32[8,128])) -> (f32[8,128]) {
  %ag = f32[8,128]{1,0} all-gather(%x), replica_groups=...
}
%micro_body.2 (p: (f32[8,128])) -> (f32[8,128]) {
  %w = f32[8,128] while(%y), condition=%c.9, body=%layer_body.1
  %ar = f32[4,4]{1,0} all-reduce(%z), to_apply=%add.3
}
ENTRY %main.9 (a: f32[2]) -> f32[2] {
  %w2 = f32[8,128] while(%q), condition=%c.8, body=%micro_body.2
  %rs = f32[16,16]{1,0} reduce-scatter(%g), replica_groups=...
}
"""


def test_nested_collective_multipliers():
    # trips: depth1 (micro) = 4, depth2 (layers) = 10
    per_type, total = collective_bytes_nested(HLO, [4.0, 10.0])
    # all-gather in layer body: 8*128*4 bytes x 4 x 10
    assert per_type["all-gather"] == 8 * 128 * 4 * 40
    # all-reduce in micro body: 4*4*4 x 4
    assert per_type["all-reduce"] == 4 * 4 * 4 * 4
    # reduce-scatter at entry: x1
    assert per_type["reduce-scatter"] == 16 * 16 * 4
    assert total == sum(per_type.values())


def test_trips_for_case_shapes():
    cfg = get_config("gemma2-9b")
    tr = trips_for_case(cfg, INPUT_SHAPES["train_4k"], 16)
    assert tr[0] == 16.0
    assert tr[1] == 21.0          # stage repeat (2 layers per iteration)
    ts = trips_for_case(cfg, INPUT_SHAPES["decode_32k"], 1)
    assert ts[0] == 21.0
    cfg2 = get_config("rwkv6-3b")
    ts2 = trips_for_case(cfg2, INPUT_SHAPES["prefill_32k"], 1)
    assert ts2[1] == 32768 // 128   # ssm chunk scan
