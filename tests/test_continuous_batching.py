"""Iteration-level continuous decode batching: admission mid-decode,
immediate eviction of finished sequences, per-iteration streaming chunk
ordering, slot-aware pool routing, and flag-off byte-identity with the
legacy run-to-completion path."""
import itertools
import time

import pytest

import repro.core.passes as passes_mod
import repro.core.pgraph as pgraph_mod
import repro.core.primitives as prims_mod
import repro.core.runtime as runtime_mod
from repro.configs.base import get_config
from repro.core import primitives as P
from repro.core.engine_pool import EnginePool
from repro.core.primitives import Graph, Primitive
from repro.core.runtime import Runtime
from repro.core.streams import TokenStream
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import SimLLMEngine, build_sim_engines


def _wait(pred, timeout=10.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if pred():
            return True
        time.sleep(0.002)
    return False


# ---------------------------------------------------------------------------
# Loop-level behavior (sim engine)

def test_admission_mid_decode():
    """A sequence submitted while another is decoding joins the running
    loop at the next free-slot admission pass — it does not wait for the
    resident batch to run to completion."""
    eng = SimLLMEngine("llm", max_batch=4, decode_ms_per_step=30.0)
    long = eng.submit_decode("long", 40)
    loop = eng.start_decode_loop()
    assert _wait(lambda: long.t_admit is not None and long.steps > 2)
    short = eng.submit_decode("short", 4)
    short.wait(60)
    assert short.t_admit is not None
    assert not long.done.is_set()       # finished entirely mid-decode
    assert short.result.split() and len(short.result.split()) == 4
    long.wait(60)
    assert loop.iterations >= 40
    assert loop.max_resident == 2       # both were resident together
    eng.stop_decode_loop()


def test_early_eviction_frees_slot():
    """A finished sequence leaves its slot immediately; a waiting
    sequence is admitted without waiting for the rest of the batch."""
    eng = SimLLMEngine("llm", max_batch=2, decode_ms_per_step=30.0)
    a = eng.submit_decode("a", 30)
    b = eng.submit_decode("b", 4)
    c = eng.submit_decode("c", 4)       # queued: both slots taken
    c.wait(60)
    assert not a.done.is_set()          # c ran and finished while a lives
    assert b.done.is_set()
    assert c.t_admit >= b.t_done        # c got b's slot after b's eviction
    a.wait(60)
    evicted = [sid for sid, _, _ in eng._decode_loop.evictions]
    assert evicted.index("b") < evicted.index("a")
    eng.stop_decode_loop()


def test_per_iteration_chunk_ordering():
    """on_text fires every iteration with monotonically growing text."""
    eng = SimLLMEngine("llm", max_batch=2, decode_ms_per_step=10.0)
    chunks = []
    out = eng.submit_decode("s", 8, on_text=chunks.append).wait(60)
    eng.stop_decode_loop()
    assert len(chunks) == 8             # one emission per iteration
    for prev, cur in zip(chunks, chunks[1:]):
        assert cur.startswith(prev) and len(cur) > len(prev)
    assert chunks[-1] == out


def test_loop_error_fails_resident_sequences():
    eng = SimLLMEngine("llm", max_batch=2)

    def boom(seqs):
        raise RuntimeError("step failed")

    eng.decode_iteration = boom
    seq = eng.submit_decode("s", 4)
    with pytest.raises(RuntimeError, match="step failed"):
        seq.wait(60)
    eng.stop_decode_loop()


# ---------------------------------------------------------------------------
# Real-engine numerics: continuous loop == legacy decode_batch

def test_real_engine_continuous_matches_legacy_tokens():
    """Greedy continuous decode must reproduce the legacy run-to-
    completion tokens exactly (same jitted step, same shapes)."""
    cfg = get_config("tiny-lite-llm")

    def run(continuous):
        eng = LLMEngine("t", cfg, max_len=128, max_batch=4)
        eng.op_prefill([{"sid": "a", "text": "system instruction words"},
                        {"sid": "b", "text": "another prompt entirely"}])
        if continuous:
            out = [eng.submit_decode("a", 8).wait(300),
                   eng.submit_decode("b", 8).wait(300)]
            eng.stop_decode_loop()
        else:
            out = [eng.op_decode([{"sid": "a", "max_new": 8}])[0],
                   eng.op_decode([{"sid": "b", "max_new": 8}])[0]]
        return out

    assert run(True) == run(False)


def test_real_engine_residency_change_and_redecode():
    """The persistent stacked decode cache must be written back on
    eviction: a sequence admitted mid-decode (residency change) and a
    SECOND decode of an evicted sid both see consistent KV state."""
    cfg = get_config("tiny-lite-llm")
    eng = LLMEngine("t", cfg, max_len=128, max_batch=4)
    eng.op_prefill([{"sid": "a", "text": "first prompt words"},
                    {"sid": "b", "text": "second prompt words"}])
    sa = eng.submit_decode("a", 12)
    sb = eng.submit_decode("b", 6)          # joins / evicts mid-flight
    ta, tb = sa.wait(300), sb.wait(300)
    assert len(sa.tokens) == 12 and len(sb.tokens) == 6
    assert ta and tb
    pos_a = eng.states["a"].pos
    t2 = eng.submit_decode("a", 5).wait(300)  # re-decode after eviction
    assert t2 and len(t2.split()) >= 1
    assert eng.states["a"].pos == pos_a + 5
    eng.stop_decode_loop()


def test_real_engine_meter_advances_per_iteration():
    """KV occupancy under continuous decode grows one token per
    iteration, and decode slots are released at eviction."""
    cfg = get_config("tiny-lite-llm")
    eng = LLMEngine("t", cfg, max_len=128, max_batch=4)
    eng.op_prefill([{"sid": "a", "text": "some words here"}])
    base = eng.meter.tokens()
    seq = eng.submit_decode("a", 6)
    assert _wait(lambda: eng.meter.slots_used() == 1, timeout=60)
    seq.wait(300)
    assert eng.meter.tokens() == base + 6
    assert _wait(lambda: eng.meter.slots_used() == 0)
    assert eng.meter.slots_free() == eng.max_batch
    eng.stop_decode_loop()


# ---------------------------------------------------------------------------
# Slot-aware pool routing

def test_slot_aware_decode_routing():
    pool = EnginePool.replicate(
        SimLLMEngine("llm", max_batch=2, decode_ms_per_step=50.0), 2,
        name="llm")
    assert pool.decode_slots_free(0) == 2
    long0 = pool[0].submit_decode("l0", 500)
    long1 = pool[0].submit_decode("l1", 500)
    assert _wait(lambda: pool[0].decode_slots_free() == 0)
    # replica 1 has free slots -> wins even though loads are equal
    assert pool.least_loaded_decode() == 1
    pool[0].stop_decode_loop()
    long0.done.wait(10)
    long1.done.wait(10)
    pool[1].stop_decode_loop()


# ---------------------------------------------------------------------------
# Runtime decode-slot dispatch mode

def _gen_graph(max_new=24):
    g = Graph(query_id="q")
    pre = Primitive(op=P.PREFILL, engine="llm", component="gen",
                    consumes={"question"}, produces={"state:s"},
                    config={"sid": "s", "instruction": "hello world",
                            "parts": [("instr", None),
                                      ("q", "question")]})
    dec = Primitive(op=P.DECODE, engine="llm", component="gen",
                    consumes={"state:s"}, produces={"draft"},
                    config={"sid": "s", "max_new": max_new})
    for p in (pre, dec):
        g.add(p)
    g.edge(pre, dec)
    g.assign_depths()
    return g


def test_runtime_dispatches_decode_into_loop():
    llm = SimLLMEngine("llm", decode_ms_per_step=10.0)
    rt = Runtime({"llm": llm}, policy="to", continuous_batching=True)
    ctx = rt.submit(_gen_graph(), {"question": "x"}, output_key="draft")
    assert ctx.done.wait(60)
    assert ctx.error is None
    sched = rt.scheds["llm"]
    assert sched.decode_submits == [(1, P.DECODE)]
    # the decode went through the loop, not a run-to-completion batch
    assert all(op != P.DECODE for _, op in sched.batches)
    assert llm._decode_loop.iterations >= 24
    rt.shutdown()


def test_runtime_streaming_chunks_under_continuous():
    """Streaming + continuous: per-iteration TokenStream chunks, ordered,
    and the final store value is the sealed plain text."""
    llm = SimLLMEngine("llm", decode_ms_per_step=30.0)
    rt = Runtime({"llm": llm}, policy="to", streaming=True,
                 continuous_batching=True)
    ctx = rt.submit(_gen_graph(), {"question": "x"}, output_key="draft")
    stream = None

    def saw_stream():
        nonlocal stream
        v = ctx.store.get("draft")
        if isinstance(v, TokenStream):
            stream = v
            return True
        return False

    assert _wait(saw_stream), "stream never appeared in store"
    deltas = list(stream)               # consume until close
    assert ctx.done.wait(60)
    assert ctx.error is None
    # per-iteration emission: ~one delta per decoded token
    assert len(deltas) >= 12
    assert "".join(deltas) == ctx.store["draft"]
    assert isinstance(ctx.store["draft"], str)
    rt.shutdown()


def test_pooled_continuous_releases_ledger_on_error():
    """A decode that errors in the loop must still release the pool's
    in-flight token ledger (routing would otherwise skew forever)."""
    pool = EnginePool.replicate(SimLLMEngine("llm"), 2, name="llm")

    def boom(seqs):
        raise RuntimeError("step failed")

    for rep in pool:
        rep.decode_iteration = boom
    rt = Runtime({"llm": pool}, policy="to", continuous_batching=True)
    ctx = rt.submit(_gen_graph(max_new=8), {"question": "x"},
                    output_key="draft")
    assert ctx.done.wait(60)
    assert isinstance(ctx.error, RuntimeError)
    # queued/inflight decode tokens released despite the error (resident
    # KV from the prefill stays, as on the legacy failure path)
    assert _wait(lambda: all(l.queued == 0 and l.inflight == 0
                             for l in pool._loads))
    rt.shutdown()


def test_pooled_continuous_keeps_sequence_affinity():
    pool = EnginePool.replicate(SimLLMEngine("llm"), 2, name="llm")
    rt = Runtime({"llm": pool}, policy="to", continuous_batching=True)
    ctx = rt.submit(_gen_graph(max_new=8), {"question": "x"},
                    output_key="draft")
    assert ctx.done.wait(60)
    assert ctx.error is None
    sched = rt.scheds["llm"]
    decode_routes = [r for r in sched.routes if r[1] == P.DECODE]
    prefill_routes = [r for r in sched.routes if r[1] == P.PREFILL]
    assert decode_routes and prefill_routes
    # the decode followed its prefill's replica (KV affinity)
    assert decode_routes[0][0] == prefill_routes[0][0]
    rt.shutdown()


# ---------------------------------------------------------------------------
# Byte-identity: flag off reproduces the legacy path; flag on produces
# the same final text (the sim decode's text is decided by state, not by
# batching), while actually running through the loop.

def _reset_counters():
    runtime_mod._qid = itertools.count()
    prims_mod._counter = itertools.count()
    pgraph_mod._sid = itertools.count()
    passes_mod._uid = itertools.count()


def _answer(continuous: bool):
    from repro.core.apps import advanced_rag
    from repro.core.teola import Teola
    from repro.training.data import doc_corpus
    _reset_counters()
    engines = build_sim_engines()
    orch = Teola(advanced_rag(engines), engines,
                 continuous_batching=continuous)
    out, ctx = orch.query({"question": "what is fact 3 about optics",
                           "docs": doc_corpus(2)}, timeout=300)
    assert ctx.error is None
    iters = sum(e._decode_loop.iterations
                for e in engines.values()
                if getattr(e, "_decode_loop", None) is not None)
    orch.shutdown()
    return out, iters


def test_flag_off_byte_identical_and_flag_on_equivalent():
    legacy, legacy_iters = _answer(continuous=False)
    cont, cont_iters = _answer(continuous=True)
    assert legacy_iters == 0            # flag off: loop never ran
    assert cont_iters > 0               # flag on: decodes went via loop
    assert cont == legacy               # identical final answer
