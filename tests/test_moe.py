"""MoE routing invariants + expert-parallel vs dense equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import moe as moe_mod


def _cfg(capacity_factor=8.0, experts=4, topk=2):
    base = get_config("qwen2-moe-a2.7b").reduced()
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, num_experts=experts,
                                      top_k=topk,
                                      capacity_factor=capacity_factor))


def test_router_topk_gates_normalized():
    cfg = _cfg()
    p = moe_mod.init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (32, cfg.d_model))
    gates, idx, logits = moe_mod.router_probs(cfg, p["router"], x)
    assert gates.shape == (32, cfg.moe.top_k)
    assert idx.shape == (32, cfg.moe.top_k)
    if cfg.moe.norm_topk_prob:
        np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0,
                                   rtol=1e-4)
    # indices within range and distinct per token
    assert int(idx.max()) < cfg.moe.num_experts
    for row in np.asarray(idx):
        assert len(set(row)) == len(row)


def test_aux_loss_uniform_router_is_one():
    cfg = _cfg()
    T, E = 512, cfg.moe.num_experts
    logits = jnp.zeros((T, E))
    idx = jnp.stack([jnp.arange(T) % E, (jnp.arange(T) + 1) % E], -1)
    loss = moe_mod.aux_load_balance_loss(cfg, logits, idx)
    np.testing.assert_allclose(float(loss), 1.0, rtol=1e-3)


def test_ep_matches_dense_single_device():
    """shard_map EP path (tp=1 trivial mesh) must equal the dense path
    when capacity is large enough that nothing drops."""
    cfg = _cfg(capacity_factor=8.0)
    p = moe_mod.init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    dense, _ = moe_mod.routed_dense(cfg, p, x.reshape(-1, cfg.d_model))

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ep, _ = moe_mod.routed_ep(cfg, p, x.reshape(-1, cfg.d_model), mesh)
    np.testing.assert_allclose(np.asarray(ep), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens():
    """With a tiny capacity factor the EP path drops overflow tokens
    (outputs differ from dense on some tokens but are finite)."""
    cfg = _cfg(capacity_factor=0.25)
    p = moe_mod.init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ep, _ = moe_mod.routed_ep(cfg, p, x, mesh)
    assert np.isfinite(np.asarray(ep)).all()
    dense, _ = moe_mod.routed_dense(cfg, p, x)
    assert not np.allclose(np.asarray(ep), np.asarray(dense))


def test_moe_ffn_shared_experts_added():
    cfg = _cfg()
    p = moe_mod.init_moe_params(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model))
    full, _ = moe_mod.moe_ffn(cfg, p, x)
    routed, _ = moe_mod.routed_dense(cfg, p, x.reshape(-1, cfg.d_model))
    shared = moe_mod.shared_expert_ffn(cfg, p, x)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(routed.reshape(x.shape) + shared),
                               rtol=2e-4, atol=2e-4)
