"""Examples must stay runnable (subprocess smoke tests)."""
import os
import subprocess
import sys


ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def _run(args, timeout=900):
    r = subprocess.run([sys.executable] + args,
                       env={**os.environ, "PYTHONPATH": SRC,
                            "REPRO_SIM_SPEED": "16"},
                       cwd=ROOT, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return r.stdout


def test_quickstart():
    out = _run([os.path.join(ROOT, "examples", "quickstart.py")])
    assert "optimized e-graph" in out
    assert "end-to-end latency" in out


def test_serve_batched_driver():
    out = _run([os.path.join(ROOT, "examples", "serve_batched.py"), "3"])
    assert "served 3 queries" in out
    assert "topology-aware batching" in out


def test_train_tiny_short():
    out = _run([os.path.join(ROOT, "examples", "train_tiny.py"), "30"])
    assert "checkpoint round-trip OK" in out


def test_serve_launcher_sim():
    out = _run(["-m", "repro.launch.serve", "--app", "naive_rag", "--sim",
                "--queries", "3", "--scheme", "Teola"])
    assert "avg latency" in out


def test_train_launcher_reduced():
    out = _run(["-m", "repro.launch.train", "--arch", "rwkv6-3b",
                "--reduced", "--steps", "6"])
    assert "step    5" in out or "step 5" in out.replace("  ", " ")
