"""Property-based tests (hypothesis) for system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import primitives as P
from repro.core.passes import graph_opt, pass1_prune_dependencies
from repro.core.primitives import Graph, Primitive
from repro.engines.tokenizer import HashTokenizer
from repro.serving import kv_cache as kvc


# ---------------------------------------------------------------------------
# Graph invariants under optimization

@st.composite
def chain_graphs(draw):
    """Random chain workflows: sequences of primitives with random data
    keys; some edges carry data, some are template-order only."""
    n = draw(st.integers(3, 12))
    g = Graph(query_id="q")
    prev = None
    keys = [f"k{i}" for i in range(n + 1)]
    for i in range(n):
        consumes = set()
        if i > 0 and draw(st.booleans()):
            consumes.add(keys[draw(st.integers(0, i - 1))])
        prim = Primitive(op=P.EMBEDDING, engine="e", component=f"c{i}",
                         consumes=consumes, produces={keys[i]})
        g.add(prim)
        if prev is not None:
            g.edge(prev, prim)
        prev = prim
    return g


@given(chain_graphs())
@settings(max_examples=60, deadline=None)
def test_pass1_edges_are_exactly_data_deps(g):
    pass1_prune_dependencies(g)
    g.validate()
    for n in g.nodes.values():
        for cpid in n.children:
            c = g.nodes[cpid]
            assert n.produces & c.consumes
    # and every resolvable consumed key has an in-edge
    producers = {k: n.pid for n in g.nodes.values() for k in n.produces}
    for n in g.nodes.values():
        for k in n.consumes:
            if k in producers and producers[k] != n.pid:
                assert producers[k] in n.parents


@given(chain_graphs())
@settings(max_examples=30, deadline=None)
def test_depth_assignment_monotone(g):
    pass1_prune_dependencies(g)
    g.assign_depths()
    for n in g.nodes.values():
        for cpid in n.children:
            assert n.depth > g.nodes[cpid].depth


# ---------------------------------------------------------------------------
# Ring-buffer cache vs linear cache

@given(st.integers(2, 6), st.integers(1, 40), st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_ring_slot_positions_consistent(w_exp, length, _):
    W = 2 ** w_exp
    length_v = jnp.array([length])
    slots = np.asarray(kvc.slot_positions_ring(W, length_v))[0]
    for i, p in enumerate(slots):
        if p >= 0:
            assert p % W == i
            assert length - W <= p < length
    valid = {int(p) for p in slots if p >= 0}
    expect = set(range(max(0, length - W), length))
    assert valid == expect


@given(st.integers(1, 31), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_ring_write_matches_linear_tail(pos, s):
    W, D = 16, 4
    buf_r = jnp.zeros((1, W, D))
    buf_l = jnp.zeros((1, 64, D))
    chunk = jnp.arange(s * D, dtype=jnp.float32).reshape(1, s, D) + 1
    br = kvc.write_ring(buf_r, chunk, jnp.array([pos]))
    bl = kvc.write_linear(buf_l, chunk, jnp.array([pos]))
    slots = np.asarray(kvc.slot_positions_ring(W, jnp.array([pos + s])))[0]
    for i, p in enumerate(slots):
        if pos <= p < pos + s:
            np.testing.assert_allclose(np.asarray(br[0, i]),
                                       np.asarray(bl[0, p]))


# ---------------------------------------------------------------------------
# Tokenizer

@given(st.lists(st.sampled_from(
    "the quick brown fox jumps over lazy dog alpha beta gamma".split()),
    min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_tokenizer_roundtrip(words):
    tok = HashTokenizer(512)
    text = " ".join(words)
    assert tok.decode(tok.encode(text)) == text


# ---------------------------------------------------------------------------
# Attention position-mask invariants

@given(st.integers(0, 20), st.integers(1, 8), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_position_mask_causal(prefix, s, window):
    from repro.models.attention import position_mask
    T = prefix + s
    q_pos = (prefix + jnp.arange(s))[None]
    k_pos = jnp.arange(T)[None]
    m = np.asarray(position_mask(q_pos, k_pos, window))[0]
    for i in range(s):
        for j in range(T):
            expect = j <= prefix + i and j > prefix + i - window
            assert m[i, j] == expect


# ---------------------------------------------------------------------------
# Optimizer

@given(st.floats(1e-5, 1e-2), st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_adamw_descends_quadratic(lr, steps):
    from repro.training.optimizer import AdamWConfig, adamw_update, \
        init_opt_state
    cfg = AdamWConfig(lr=lr, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < l0
