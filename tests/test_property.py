"""Property-based tests (hypothesis) for system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core import primitives as P
from repro.core.passes import pass1_prune_dependencies
from repro.core.primitives import Graph, Primitive
from repro.engines.tokenizer import HashTokenizer
from repro.serving import kv_cache as kvc


# ---------------------------------------------------------------------------
# Graph invariants under optimization

@st.composite
def chain_graphs(draw):
    """Random chain workflows: sequences of primitives with random data
    keys; some edges carry data, some are template-order only."""
    n = draw(st.integers(3, 12))
    g = Graph(query_id="q")
    prev = None
    keys = [f"k{i}" for i in range(n + 1)]
    for i in range(n):
        consumes = set()
        if i > 0 and draw(st.booleans()):
            consumes.add(keys[draw(st.integers(0, i - 1))])
        prim = Primitive(op=P.EMBEDDING, engine="e", component=f"c{i}",
                         consumes=consumes, produces={keys[i]})
        g.add(prim)
        if prev is not None:
            g.edge(prev, prim)
        prev = prim
    return g


@given(chain_graphs())
@settings(max_examples=60, deadline=None)
def test_pass1_edges_are_exactly_data_deps(g):
    pass1_prune_dependencies(g)
    g.validate()
    for n in g.nodes.values():
        for cpid in n.children:
            c = g.nodes[cpid]
            assert n.produces & c.consumes
    # and every resolvable consumed key has an in-edge
    producers = {k: n.pid for n in g.nodes.values() for k in n.produces}
    for n in g.nodes.values():
        for k in n.consumes:
            if k in producers and producers[k] != n.pid:
                assert producers[k] in n.parents


@given(chain_graphs())
@settings(max_examples=30, deadline=None)
def test_depth_assignment_monotone(g):
    pass1_prune_dependencies(g)
    g.assign_depths()
    for n in g.nodes.values():
        for cpid in n.children:
            assert n.depth > g.nodes[cpid].depth


# ---------------------------------------------------------------------------
# Ring-buffer cache vs linear cache

@given(st.integers(2, 6), st.integers(1, 40), st.integers(2, 16))
@settings(max_examples=40, deadline=None)
def test_ring_slot_positions_consistent(w_exp, length, _):
    W = 2 ** w_exp
    length_v = jnp.array([length])
    slots = np.asarray(kvc.slot_positions_ring(W, length_v))[0]
    for i, p in enumerate(slots):
        if p >= 0:
            assert p % W == i
            assert length - W <= p < length
    valid = {int(p) for p in slots if p >= 0}
    expect = set(range(max(0, length - W), length))
    assert valid == expect


@given(st.integers(1, 31), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_ring_write_matches_linear_tail(pos, s):
    W, D = 16, 4
    buf_r = jnp.zeros((1, W, D))
    buf_l = jnp.zeros((1, 64, D))
    chunk = jnp.arange(s * D, dtype=jnp.float32).reshape(1, s, D) + 1
    br = kvc.write_ring(buf_r, chunk, jnp.array([pos]))
    bl = kvc.write_linear(buf_l, chunk, jnp.array([pos]))
    slots = np.asarray(kvc.slot_positions_ring(W, jnp.array([pos + s])))[0]
    for i, p in enumerate(slots):
        if pos <= p < pos + s:
            np.testing.assert_allclose(np.asarray(br[0, i]),
                                       np.asarray(bl[0, p]))


# ---------------------------------------------------------------------------
# BlockAllocator invariants (paged KV pool)

@given(st.integers(3, 24),
       st.lists(st.tuples(st.integers(0, 2), st.integers(0, 100)),
                min_size=1, max_size=80))
@settings(max_examples=80, deadline=None)
def test_block_allocator_conservation(num_blocks, program):
    """Random alloc / COW-fork (incref) / release (decref) sequences:
    free-list + allocated blocks always partition the capacity, per-block
    refcounts always equal the references we hold, the reserved pad block
    is never handed out, and releasing everything restores the full free
    list."""
    a = kvc.BlockAllocator(num_blocks)
    held = []                                  # our refs (multiset)
    for op, idx in program:
        if op == 0:                            # alloc (grow a table)
            if a.free_blocks() > 0:
                b = a.alloc()
                assert b != kvc.PAD_BLOCK      # pad block never allocated
                held.append(b)
            else:
                with pytest.raises(kvc.OutOfBlocks):
                    a.alloc()
        elif op == 1 and held:                 # COW fork: share a block
            b = held[idx % len(held)]
            a.incref(b)
            held.append(b)
        elif op == 2 and held:                 # release one reference
            b = held.pop(idx % len(held))
            a.decref(b)
        # conservation + refcount ground truth after EVERY step
        assert a.free_blocks() + a.used_blocks() == a.capacity
        assert a.used_blocks() == len(set(held))
        for b in set(held):
            assert a.refcount(b) == held.count(b)
    for b in held:
        a.decref(b)
    assert a.free_blocks() == a.capacity and a.used_blocks() == 0


@given(st.integers(2, 6), st.integers(0, 80), st.integers(0, 80),
       st.booleans())
@settings(max_examples=60, deadline=None)
def test_trim_table_rollback_conserves_blocks(bs_exp, pos_hi, pos_lo,
                                              share_tail):
    """Speculative-rollback trims: a table grown to cover pos_hi then
    trimmed to pos_lo keeps exactly blocks_for(pos_lo) entries, returns
    the difference to the free list (shared tail blocks lose only OUR
    reference), and never underflows a refcount."""
    bs = 2 ** bs_exp
    pos_hi, pos_lo = max(pos_hi, pos_lo), min(pos_hi, pos_lo)
    need = kvc.blocks_for(pos_hi, bs)
    a = kvc.BlockAllocator(max(2, need + 2))
    table = [a.alloc() for _ in range(need)]
    if share_tail and table:
        a.incref(table[-1])                    # someone else holds it too
    freed = kvc.trim_table(a, table, pos_lo, bs)
    keep = kvc.blocks_for(pos_lo, bs)
    assert len(table) == keep and freed == need - keep
    assert a.used_blocks() == keep + (1 if share_tail and need > keep
                                      else 0)
    assert a.free_blocks() + a.used_blocks() == a.capacity
    # refcounts of kept blocks untouched
    for b in table:
        assert a.refcount(b) >= 1


@given(st.lists(st.integers(0, 1), min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_block_allocator_refcount_never_negative(program):
    """decref below zero must trip the allocator's assertion rather than
    silently corrupting the free list."""
    a = kvc.BlockAllocator(8)
    b = a.alloc()
    refs = 1
    for op in program:
        if refs == 0:
            # the block is back on the free list: BOTH ref ops must trip
            # the guard assertion instead of corrupting the free list
            with pytest.raises(AssertionError):
                a.incref(b) if op == 0 else a.decref(b)
        elif op == 0:
            a.incref(b)
            refs += 1
        else:
            a.decref(b)
            refs -= 1
        assert a.used_blocks() == (1 if refs else 0)


# ---------------------------------------------------------------------------
# Tokenizer

@given(st.lists(st.sampled_from(
    "the quick brown fox jumps over lazy dog alpha beta gamma".split()),
    min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_tokenizer_roundtrip(words):
    tok = HashTokenizer(512)
    text = " ".join(words)
    assert tok.decode(tok.encode(text)) == text


# ---------------------------------------------------------------------------
# Attention position-mask invariants

@given(st.integers(0, 20), st.integers(1, 8), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_position_mask_causal(prefix, s, window):
    from repro.models.attention import position_mask
    T = prefix + s
    q_pos = (prefix + jnp.arange(s))[None]
    k_pos = jnp.arange(T)[None]
    m = np.asarray(position_mask(q_pos, k_pos, window))[0]
    for i in range(s):
        for j in range(T):
            expect = j <= prefix + i and j > prefix + i - window
            assert m[i, j] == expect


# ---------------------------------------------------------------------------
# Optimizer

@given(st.floats(1e-5, 1e-2), st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_adamw_descends_quadratic(lr, steps):
    from repro.training.optimizer import AdamWConfig, adamw_update, \
        init_opt_state
    cfg = AdamWConfig(lr=lr, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(cfg, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < l0
