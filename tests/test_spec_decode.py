"""Speculative decoding: token-identity vs baseline greedy decode across
dense/paged KV and legacy/continuous decode paths, rollback block
hygiene, drafter behavior, pool-aware draft/target placement, sim-engine
step accounting, and serve.py flag validation."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.engine_pool import EnginePool, pair_replicas
from repro.engines.llm_engine import LLMEngine
from repro.engines.sim_engines import SimLLMEngine
from repro.engines.spec_decode import PromptLookupDrafter
from repro.serving import kv_cache as kvc

PROMPTS = [("a", "alpha beta gamma delta"),
           ("b", "one two three four five six"),
           ("c", "the quick brown fox jumps")]


def _engine(*, paged=False, spec=False, draft=None, k=3, max_len=128,
            **kw):
    eng = LLMEngine("e", get_config("tiny-core-llm"), max_len=max_len,
                    seed=0, paged=paged, block_size=8, **kw)
    if spec:
        eng.enable_speculative(draft=draft, k=k)
    return eng


def _prefill(eng, prompts=PROMPTS):
    eng.op_prefill([{"sid": s, "text": t} for s, t in prompts])


def _same_weights_draft(max_len=128):
    return LLMEngine("draft", get_config("tiny-core-llm"), max_len=max_len,
                     seed=0)


# ---------------------------------------------------------------------------
# token identity: run-to-completion (legacy) decode path

@pytest.mark.parametrize("paged", [False, True])
def test_spec_matches_baseline_legacy(paged):
    """op_decode with speculative decoding on must produce the exact
    baseline greedy token streams — mixed lengths, dense and paged."""
    reqs = [{"sid": "a", "max_new": 20}, {"sid": "b", "max_new": 7},
            {"sid": "c", "max_new": 13}]
    base = _engine()
    _prefill(base)
    expect = base.op_decode([dict(r) for r in reqs])
    eng = _engine(paged=paged, spec=True)
    _prefill(eng)
    assert eng.op_decode([dict(r) for r in reqs]) == expect
    s = eng.spec.stats
    assert s["tokens_emitted"] == 40
    assert s["target_steps"] + s["fallback_steps"] > 0


@pytest.mark.parametrize("paged", [False, True])
def test_spec_matches_baseline_draft_engine(paged):
    """A REAL draft engine (same weights: the acceptance ceiling) must
    stay token-identical while cutting target steps to ~n/(k+1)."""
    base = _engine()
    _prefill(base)
    expect = base.op_decode([{"sid": "a", "max_new": 24}])
    eng = _engine(paged=paged, spec=True, draft=_same_weights_draft(), k=3)
    _prefill(eng)
    assert eng.op_decode([{"sid": "a", "max_new": 24}]) == expect
    s = eng.spec.stats
    assert s["seq_steps"] <= -(-24 // 4) + 1      # near-perfect acceptance
    assert s["accepted"] >= 18


# ---------------------------------------------------------------------------
# token identity: continuous decode loop (incl. mid-stream admission)

@pytest.mark.parametrize("paged", [False, True])
def test_spec_matches_baseline_continuous(paged):
    """Speculative mode inside the continuous decode loop: staggered
    lengths force mid-stream evictions (and admissions once slots free
    up); streams must equal the non-speculative loop's streams."""
    outs = {}
    for tag, spec in (("base", False), ("spec", True)):
        eng = _engine(paged=paged, spec=spec, max_batch=2)
        _prefill(eng)
        # 3 seqs into 2 slots: c is admitted mid-stream after a evicts
        seqs = [eng.submit_decode("a", 5), eng.submit_decode("b", 17),
                eng.submit_decode("c", 11)]
        outs[tag] = tuple(s.wait(120) for s in seqs)
        eng.stop_decode_loop()
        if spec:
            assert eng.spec.stats["target_steps"] > 0
    assert outs["base"] == outs["spec"]


def test_spec_continuous_draft_engine_paged():
    """Loop + paged target + real draft engine: identity holds and the
    loop finishes in far fewer iterations than tokens."""
    base = _engine(max_batch=4)
    _prefill(base)
    sb = [base.submit_decode(s, 16) for s, _ in PROMPTS]
    expect = tuple(s.wait(120) for s in sb)
    base.stop_decode_loop()

    eng = _engine(paged=True, spec=True, draft=_same_weights_draft(), k=3,
                  max_batch=4)
    _prefill(eng)
    seqs = [eng.submit_decode(s, 16) for s, _ in PROMPTS]
    assert tuple(s.wait(120) for s in seqs) == expect
    loop = eng._decode_loop
    assert loop.iterations < 16          # 48 tokens in < 16 loop passes
    eng.stop_decode_loop()


def test_spec_rollback_frees_overshoot_blocks():
    """Rejected draft tokens must not retain pool blocks: after release
    the allocator is empty, and DURING decode the resident footprint
    stays within the accepted positions' block need."""
    eng = _engine(paged=True, spec=True, k=4)
    _prefill(eng, PROMPTS[:1])
    eng.op_decode([{"sid": "a", "max_new": 10}])
    st = eng.states["a"]
    assert len(st.table) == kvc.blocks_for(st.pos, 8)   # trimmed exactly
    eng.release("a")
    assert eng.alloc.used_blocks() == 0


def test_spec_prefix_fork_identity():
    """Speculative decode on a COW-forked instruction prefix (the warmed
    op_prefill path) must match the cold baseline."""
    instr = " ".join(f"w{i}" for i in range(24))
    outs = {}
    for tag, spec in (("base", False), ("spec", True)):
        eng = _engine(paged=True, spec=spec)
        eng.use_prefix_cache = True
        eng.get_prefix_state(instr)
        eng.op_prefill([{"sid": "q", "text": instr + " tail question"}])
        outs[tag] = eng.op_decode([{"sid": "q", "max_new": 12}])
    assert outs["base"] == outs["spec"]


# ---------------------------------------------------------------------------
# drafters

def test_prompt_lookup_drafter_matches_ngrams():
    d = PromptLookupDrafter(max_ngram=3)
    # context repeats "7 8 9" after "5 6" twice — trailing [5, 6] matches
    ctx = [1, 2, 5, 6, 7, 8, 9, 3, 4, 5, 6]
    assert d.propose(ctx, 3) == [7, 8, 9]
    assert d.propose(ctx, 5) == [7, 8, 9, 3, 4]
    # no match: repeat last token
    assert d.propose([1, 2, 3], 2) == [3, 3]
    assert d.propose([], 2) == [1, 1]


def test_engine_drafter_failure_degrades_to_lookup():
    """A draft engine that cannot serve (tiny paged pool) must never fail
    the target decode — proposals fall back to prompt lookup."""
    draft = LLMEngine("d", get_config("tiny-core-llm"), max_len=128,
                      seed=0, paged=True, block_size=8, num_blocks=2)
    base = _engine()
    _prefill(base, PROMPTS[:1])
    expect = base.op_decode([{"sid": "a", "max_new": 10}])
    eng = _engine(spec=True, draft=draft)
    _prefill(eng, PROMPTS[:1])
    assert eng.op_decode([{"sid": "a", "max_new": 10}]) == expect


def test_enable_speculative_rejects_vocab_mismatch():
    eng = _engine()
    bad = LLMEngine("d", get_config("tiny-lite-llm"), max_len=128, seed=0)
    bad.cfg = bad.cfg  # tiny-lite has the same vocab; fabricate mismatch
    import dataclasses
    bad.cfg = dataclasses.replace(bad.cfg, vocab_size=1024)
    with pytest.raises(ValueError, match="vocab"):
        eng.enable_speculative(draft=bad)


# ---------------------------------------------------------------------------
# pool placement + sim accounting

def test_pair_replicas_index_aligned_and_cycled():
    tgt = EnginePool.replicate(SimLLMEngine("core"), 4, name="core")
    drf = EnginePool.replicate(SimLLMEngine("lite"), 2, name="lite")
    pairs = pair_replicas(tgt, drf)
    assert [t.name for t, _ in pairs] == [r.name for r in tgt.replicas]
    assert [d.name for _, d in pairs] == \
        [drf[0].name, drf[1].name, drf[0].name, drf[1].name]
    # bare engines work too
    t, d = SimLLMEngine("t"), SimLLMEngine("d")
    assert pair_replicas(t, d) == [(t, d)]


def test_attach_speculative_covers_every_target_replica():
    from repro.engines.spec_decode import attach_speculative
    cfg = get_config("tiny-core-llm")
    pool = EnginePool.replicate(
        LLMEngine("core", cfg, max_len=64, seed=0), 2, name="core")
    lite = EnginePool.replicate(
        LLMEngine("lite", get_config("tiny-core-llm"), max_len=64, seed=1),
        2, name="lite")
    specs = attach_speculative({"core_llm": pool, "lite_llm": lite}, k=2)
    assert len(specs) == 2
    for i, rep in enumerate(pool):
        assert rep.spec is specs[i]
        assert rep.spec.engine_drafter.engine is lite[i]


def test_sim_speculative_step_accounting():
    """Sim speculative mode: identical text, ~1/mean_accept_len decode
    iterations, and per-step latency carrying the draft cost."""
    plain = SimLLMEngine("p", decode_ms_per_step=1.0)
    spec = SimLLMEngine("s", decode_ms_per_step=1.0, speculative=True,
                        draft_k=4, spec_accept=0.7)
    texts = {}
    for eng in (plain, spec):
        eng.op_prefill([{"sid": "x", "text": "hello world"}])
        seq = eng.submit_decode("x", 24)
        texts[eng.name] = seq.wait(60)
        eng.stop_decode_loop()
    assert texts["p"] == texts["s"]
    mean = spec.mean_accept_len()
    assert mean > 2.0
    expect_iters = int(np.ceil(24 / mean))
    assert spec.stats["decode_iters"] <= expect_iters + 2
    assert plain.stats["decode_iters"] >= 24
    # run-to-completion: modeled duration reflects fewer (costlier) steps
    plain.op_decode([{"sid": "x", "max_new": 24}])
    spec.op_decode([{"sid": "x", "max_new": 24}])
    assert spec.stats["busy_ms"] < plain.stats["busy_ms"]


def test_trim_table_frees_only_overshoot():
    a = kvc.BlockAllocator(10)
    table = [a.alloc() for _ in range(5)]
    shared = table[4]
    a.incref(shared)                     # trailing block shared elsewhere
    freed = kvc.trim_table(a, table, pos_end=17, block_size=8)  # keep 3
    assert freed == 2 and len(table) == 3
    assert a.refcount(shared) == 1       # released our ref, not theirs
    assert a.used_blocks() == 4          # 3 kept + the shared survivor
    assert kvc.trim_table(a, table, 17, 8) == 0   # idempotent


# ---------------------------------------------------------------------------
# serve.py flag validation (satellite)

def _validate(argv):
    from repro.launch.serve import build_parser, validate_args
    ap = build_parser()
    args = ap.parse_args(argv)
    validate_args(ap, args)
    return args


@pytest.mark.parametrize("argv,msg", [
    (["--speculative"], "--continuous-batching"),
    (["--speculative", "--continuous-batching", "--scheme",
      "LlamaDist-TO"], "--scheme Teola"),
    (["--speculative", "--continuous-batching", "--draft-k", "0"],
     "--draft-k must be >= 1"),
    (["--draft-k", "4"], "--draft-k requires --speculative"),
    (["--spec-drafter", "ngram"], "--spec-drafter requires"),
    (["--sim", "--speculative", "--continuous-batching",
      "--spec-drafter", "lite_llm"], "real engines"),
])
def test_serve_rejects_incompatible_flags(argv, msg, capsys):
    with pytest.raises(SystemExit) as e:
        _validate(argv)
    assert e.value.code == 2             # argparse error, not a traceback
    assert msg in capsys.readouterr().err


def test_serve_accepts_valid_speculative_flags():
    args = _validate(["--speculative", "--continuous-batching"])
    assert args.draft_k == 4 and args.spec_drafter == "ngram"
    args = _validate(["--speculative", "--continuous-batching",
                      "--draft-k", "6", "--spec-drafter", "lite_llm"])
    assert args.draft_k == 6 and args.spec_drafter == "lite_llm"
    args = _validate([])                 # plain serve untouched
    assert not args.speculative
