"""End-to-end runtime tests on real JAX engines and on sim engines."""

import pytest

from repro.core.apps import (advanced_rag, build_engines,
                             contextual_retrieval, naive_rag, search_gen)
from repro.core.teola import AutoGenLike, LlamaDist, LlamaDistPC, Teola
from repro.engines.sim_engines import build_sim_engines
from repro.training.data import doc_corpus

Q = {"question": "what is fact 3 about optics", "docs": doc_corpus(2)}


@pytest.fixture(scope="module")
def real_engines():
    return build_engines()


def test_real_engines_naive_rag_e2e(real_engines):
    app = naive_rag(real_engines)
    teola = Teola(app, real_engines)
    out, ctx = teola.query(dict(Q), timeout=600)
    assert isinstance(out, str) and len(out) > 0
    assert ctx.error is None
    # retrieval actually hit the question's topic
    texts = " ".join(c["text"] for c in ctx.store["retrieved"])
    assert "optics" in texts
    teola.shutdown()


def test_teola_and_llamadist_same_retrieval(real_engines):
    """Orchestration must not change WHAT is computed: same engines, same
    query -> same retrieved chunk set, regardless of granularity."""
    app = naive_rag(real_engines)
    t = Teola(app, real_engines)
    _, ctx_t = t.query(dict(Q), timeout=600)
    t.shutdown()
    l = LlamaDist(app, real_engines)
    _, ctx_l = l.query(dict(Q), timeout=600)
    l.shutdown()
    top_t = {c["text"] for c in ctx_t.store["retrieved"]}
    top_l = {c["text"] for c in ctx_l.store["retrieved"]}
    assert top_t == top_l


@pytest.mark.parametrize("mk", [naive_rag, advanced_rag, search_gen,
                                contextual_retrieval])
@pytest.mark.parametrize("cls", [Teola, LlamaDist, LlamaDistPC,
                                 AutoGenLike])
def test_all_apps_all_schemes_sim(mk, cls):
    engines = build_sim_engines()
    app = mk(engines)
    orch = cls(app, engines)
    out, ctx = orch.query(dict(Q), timeout=300)
    assert ctx.error is None
    assert out is not None
    assert ctx.t_done is not None
    orch.shutdown()


def test_concurrent_queries_all_complete():
    engines = build_sim_engines()
    app = advanced_rag(engines)
    teola = Teola(app, engines)
    ctxs = [teola.submit(dict(Q)) for _ in range(6)]
    for c in ctxs:
        assert c.done.wait(300)
        assert c.error is None
        assert c.store.get("answer")
    teola.shutdown()


def test_llm_states_released_after_query():
    engines = build_sim_engines()
    app = advanced_rag(engines)
    teola = Teola(app, engines)
    _, ctx = teola.query(dict(Q), timeout=300)
    assert len(engines["core_llm"].states) == 0
    teola.shutdown()


def test_teola_not_slower_than_llamadist_sim():
    """The headline claim, in its weakest testable form on sim engines."""
    lat = {}
    for cls, name in [(LlamaDist, "llamadist"), (Teola, "teola")]:
        engines = build_sim_engines()
        app = advanced_rag(engines)
        orch = cls(app, engines)
        _, ctx = orch.query(dict(Q), timeout=300)
        lat[name] = ctx.latency
        orch.shutdown()
    assert lat["teola"] < lat["llamadist"] * 1.05


def test_condition_gates_search():
    engines = build_sim_engines()
    app = search_gen(engines)
    teola = Teola(app, engines)
    # predicate 'never' -> need_search False -> empty web results
    out, ctx = teola.query(dict(Q),
                           C={"proxy_judge": {"predicate": "never"}},
                           timeout=300)
    assert ctx.store["need_search"] is False
    assert ctx.store["web_results"] == []
    teola.shutdown()
